"""Serving hardening through the real services (SpectrumService et al.)."""

import numpy as np
import pytest

import repro.xfft as xfft
from repro import obs
from repro.resilience import (
    FaultPlan,
    FaultSpec,
    Overloaded,
    ServicePolicy,
    configure,
    quarantine,
)
from repro.serve.engine import SpectrumRequest, SpectrumService
from repro.serve.imaging import ImagingService


def _requests(rng, n=3, shape=(8, 8)):
    return [
        SpectrumRequest(frame=rng.standard_normal(shape).astype(np.float32))
        for _ in range(n)
    ]


def test_spectrum_service_sheds_past_max_queue(rng):
    svc = SpectrumService(policy=ServicePolicy(max_queue=2))
    with obs.capture() as trace:
        with pytest.raises(Overloaded):
            svc.serve(_requests(rng, n=3))
    (e,) = trace.select("serve.shed")
    assert e["service"] == "spectrum"
    assert trace.select("serve.batch") == []  # shed BEFORE any group ran


def test_spectrum_service_retries_injected_batch_fault(rng):
    svc = SpectrumService(policy=ServicePolicy(max_retries=1, backoff_s=0.0))
    reqs = _requests(rng, n=2)
    plan = FaultPlan(FaultSpec("serve.batch", mode="error", times=1))
    with obs.capture() as trace, xfft.config(faults=plan):
        out = svc.serve(reqs)
    assert all(r.done for r in out)
    np.testing.assert_allclose(
        out[0].spectrum, np.fft.rfft2(np.asarray(reqs[0].frame)),
        rtol=1e-4, atol=1e-4,
    )
    assert len(trace.select("resilience.retry")) == 1


def test_spectrum_service_fails_over_and_skips_memo(fake_clock, rng):
    """An engine failure mid-serve: the ladder absorbs it, the workaround
    plan is NOT memoized, and after cooldown the service re-resolves."""
    configure(cooldown_s=30.0, clock=fake_clock)
    svc = SpectrumService()
    reqs = _requests(rng, n=2)
    first = None
    # Probe which engine serves this problem, then reset the bench.
    out = svc.serve(_requests(rng, n=1))
    ((_, plan),) = list(svc.plans.items())
    first = plan.variant
    svc.plans.clear()
    from repro.resilience import reset

    reset()

    faults = FaultPlan(
        FaultSpec("engine.apply", mode="error", match={"engine": first}, times=1)
    )
    # One scope for the whole exercise: the times=1 budget must span all
    # three serves (a fresh scope would re-arm the schedule from seed).
    with obs.capture() as trace, xfft.config(faults=faults):
        out = svc.serve(reqs)
        # Second serve: the memoized plan names the benched engine, so the
        # service re-resolves around it — and must NOT memoize the
        # workaround, or the bench would outlive the breaker.
        svc.serve(_requests(rng, n=1))
        fake_clock.now += 31.0
        svc.serve(_requests(rng, n=1))  # half-open probe succeeds
    assert all(r.done for r in out)
    np.testing.assert_allclose(
        out[0].spectrum, np.fft.rfft2(np.asarray(reqs[0].frame)),
        rtol=1e-4, atol=1e-4,
    )
    (failover,) = trace.select("resilience.failover")  # exactly one: no re-fail
    assert failover["engine"] == first
    assert "quarantined" in [e["outcome"] for e in trace.select("plan.resolve")]
    # Only pre-failure resolutions are memoized; no workaround plan landed.
    assert {p.variant for p in svc.plans.values()} == {first}
    assert quarantine().table() == []  # probe closed the breaker


def test_imaging_service_sheds_whole_queue(rng):
    from repro.serve.imaging import ConvolutionRequest

    svc = ImagingService(policy=ServicePolicy(max_queue=1))
    reqs = [
        ConvolutionRequest(
            image=rng.standard_normal((8, 8)).astype(np.float32),
            kernel=np.ones((3, 3), np.float32),
        )
        for _ in range(2)
    ]
    with pytest.raises(Overloaded):
        svc.serve(reqs)
    assert not any(r.done for r in reqs)  # no request half-served
