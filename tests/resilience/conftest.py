"""Resilience-suite fixtures: every test starts with a clean breaker.

The quarantine registry is process-wide (like the engine registry it
filters), so a test that opens a breaker must not leak the bench into
the next test — and a test that swaps the clock must hand wall time
back.
"""

import time

import pytest

from repro.resilience import configure, reset


@pytest.fixture(autouse=True)
def _clean_breaker():
    reset()
    configure(threshold=1, cooldown_s=30.0, clock=time.monotonic)
    yield
    reset()
    configure(threshold=1, cooldown_s=30.0, clock=time.monotonic)


@pytest.fixture
def fake_clock():
    """A settable clock: ``clock.now += 31.0`` drives a cooldown."""

    class _Clock:
        now = 0.0

        def __call__(self):
            return self.now

    return _Clock()
