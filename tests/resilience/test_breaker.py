"""Circuit-breaker state machine: every transition, on a fake clock."""

import pytest

from repro import obs
from repro.plan import problem_key
from repro.resilience import QuarantineRegistry, configure, quarantine
from repro.resilience.breaker import reset


KEY = problem_key("fft2d", (8, 8))
OTHER = problem_key("fft2d", (16, 16))


def _registry(fake_clock, threshold=1, cooldown_s=30.0):
    return QuarantineRegistry(
        threshold=threshold, cooldown_s=cooldown_s, clock=fake_clock
    )


def test_closed_by_default(fake_clock):
    reg = _registry(fake_clock)
    assert not reg.excluded("radix4", KEY)
    assert not reg.affects(KEY)
    assert reg.table() == []


def test_failure_at_threshold_opens(fake_clock):
    reg = _registry(fake_clock)
    with obs.capture() as trace:
        assert reg.record_failure("radix4", KEY, error="boom") is True
    assert reg.excluded("radix4", KEY)
    assert reg.affects(KEY)
    (e,) = trace.select("resilience.breaker")
    assert e["state"] == "open"
    assert e["engine"] == "radix4"
    assert e["failures"] == 1


def test_threshold_two_needs_two_failures(fake_clock):
    reg = _registry(fake_clock, threshold=2)
    assert reg.record_failure("radix4", KEY) is False
    assert not reg.excluded("radix4", KEY)
    assert reg.record_failure("radix4", KEY) is True
    assert reg.excluded("radix4", KEY)


def test_quarantine_is_per_problem_key(fake_clock):
    reg = _registry(fake_clock)
    reg.record_failure("radix4", KEY)
    assert reg.excluded("radix4", KEY)
    assert not reg.excluded("radix4", OTHER)  # healthy on other shapes
    assert not reg.excluded("stockham", KEY)  # other engines unaffected
    assert not reg.affects(OTHER)


def test_cooldown_admits_half_open_probe(fake_clock):
    reg = _registry(fake_clock, cooldown_s=30.0)
    reg.record_failure("radix4", KEY)
    fake_clock.now = 29.0
    assert reg.excluded("radix4", KEY)  # still cooling down
    fake_clock.now = 30.0
    with obs.capture() as trace:
        assert not reg.excluded("radix4", KEY)  # probe admitted
    (e,) = trace.select("resilience.breaker")
    assert e["state"] == "half_open"
    # half-open is non-consuming: every caller is admitted until resolved
    assert not reg.excluded("radix4", KEY)


def test_success_closes_half_open(fake_clock):
    reg = _registry(fake_clock)
    reg.record_failure("radix4", KEY)
    fake_clock.now = 31.0
    reg.excluded("radix4", KEY)  # -> half_open
    with obs.capture() as trace:
        reg.record_success("radix4", KEY)
    (e,) = trace.select("resilience.breaker")
    assert e["state"] == "closed"
    assert not reg.excluded("radix4", KEY)
    assert not reg.affects(KEY)
    assert reg.table() == []


def test_failure_in_half_open_reopens(fake_clock):
    reg = _registry(fake_clock, threshold=3)  # even below threshold
    for _ in range(3):
        reg.record_failure("radix4", KEY)
    fake_clock.now = 31.0
    reg.excluded("radix4", KEY)  # -> half_open
    assert reg.record_failure("radix4", KEY) is True  # probe answered: reopen
    assert reg.excluded("radix4", KEY)
    fake_clock.now = 60.0  # cooldown restarts from the reopen
    assert reg.excluded("radix4", KEY)
    fake_clock.now = 61.0
    assert not reg.excluded("radix4", KEY)


def test_success_on_closed_resets_failure_count(fake_clock):
    reg = _registry(fake_clock, threshold=2)
    reg.record_failure("radix4", KEY)
    reg.record_success("radix4", KEY)  # resets the count, no event needed
    assert reg.record_failure("radix4", KEY) is False  # back to 1 of 2


def test_table_rows(fake_clock):
    reg = _registry(fake_clock, cooldown_s=30.0)
    reg.record_failure("radix4", KEY, error="InjectedFault('boom')")
    fake_clock.now = 10.0
    (row,) = reg.table()
    assert row["engine"] == "radix4"
    assert row["state"] == "open"
    assert row["failures"] == 1
    assert row["cooldown_remaining_s"] == pytest.approx(20.0)
    assert "boom" in row["last_error"]
    assert KEY.cache_key() == row["key"]


def test_registry_validation():
    with pytest.raises(ValueError):
        QuarantineRegistry(threshold=0)
    with pytest.raises(ValueError):
        QuarantineRegistry(cooldown_s=0)
    with pytest.raises(ValueError):
        configure(threshold=0)
    with pytest.raises(ValueError):
        configure(cooldown_s=-1)


def test_configure_mutates_singleton_in_place(fake_clock):
    reg = quarantine()
    configure(threshold=5, cooldown_s=1.0, clock=fake_clock)
    assert quarantine() is reg  # early importers never see a stale registry
    assert reg.threshold == 5
    assert reg.cooldown_s == 1.0
    assert reg.clock is fake_clock


def test_reset_drops_all_state(fake_clock):
    configure(clock=fake_clock)
    quarantine().record_failure("radix4", KEY)
    assert quarantine().excluded("radix4", KEY)
    reset()
    assert not quarantine().excluded("radix4", KEY)
    assert quarantine().table() == []
