"""Degradation-ladder tests: the PR's acceptance flow, end to end.

With a FaultPlan injecting a deterministic failure into the first-choice
engine, ``xfft.fft2`` must return numpy-parity output, emit a
``resilience.failover`` event naming the quarantined engine, serve the
next call from the fallback without re-failing, and close the breaker
after cooldown via a successful half-open probe — all asserted from the
obs event stream.
"""

import numpy as np
import pytest

import repro.xfft as xfft
from repro import obs
from repro.plan import resolve_call
from repro.resilience import FaultPlan, FaultSpec, InjectedFault, configure, reset


SHAPE = (8, 8)


def _frame(rng):
    return (
        rng.standard_normal(SHAPE) + 1j * rng.standard_normal(SHAPE)
    ).astype(np.complex64)


def _first_choice():
    """The engine the planner picks for SHAPE — the fault target."""
    variant = resolve_call("fft2d", SHAPE).variant
    reset()  # the probe resolve must not leak breaker state
    return variant


def _assert_parity(y, x):
    np.testing.assert_allclose(np.asarray(y), np.fft.fft2(x), rtol=1e-4, atol=1e-4)


def test_acceptance_failover_quarantine_and_recovery(fake_clock, rng):
    configure(cooldown_s=30.0, clock=fake_clock)
    first = _first_choice()
    x = _frame(rng)
    plan = FaultPlan(
        FaultSpec("engine.apply", mode="error", match={"engine": first}, times=1)
    )
    with obs.capture() as trace, xfft.config(faults=plan):
        _assert_parity(xfft.fft2(x), x)   # fault fires: ladder absorbs it
        _assert_parity(xfft.fft2(x), x)   # served from fallback, no re-fail
        fake_clock.now += 31.0            # cooldown passes
        _assert_parity(xfft.fft2(x), x)   # half-open probe succeeds

    # Exactly one injection — the second call never re-consulted the
    # benched engine, or the times=1 budget would still have matched it.
    (fault,) = trace.select("resilience.fault")
    assert fault["seam"] == "engine.apply"

    (failover,) = trace.select("resilience.failover")
    assert failover["engine"] == first
    assert failover["quarantined"] is True
    assert failover["reason"] == "error"
    assert failover["kind"] == "fft2d"
    assert tuple(failover["shape"]) == SHAPE
    assert failover["next"] is not None and failover["next"] != first
    assert "InjectedFault" in failover["error"]

    # The planner routed around the bench: the post-fault resolve reports
    # outcome "quarantined", and the post-cooldown call is a plain hit.
    outcomes = [e["outcome"] for e in trace.select("plan.resolve")]
    assert outcomes[1:] == ["quarantined", "hit"]

    # Breaker lifecycle straight from the event stream.
    states = [e["state"] for e in trace.select("resilience.breaker")]
    assert states == ["open", "half_open", "closed"]
    assert all(e["engine"] == first for e in trace.select("resilience.breaker"))


def test_failed_engine_never_cached_as_fallback(fake_clock, rng):
    """Plans resolved under quarantine are workarounds, not wisdom: once
    the breaker closes, the original first choice serves again."""
    configure(cooldown_s=30.0, clock=fake_clock)
    first = _first_choice()
    x = _frame(rng)
    plan = FaultPlan(
        FaultSpec("engine.apply", mode="error", match={"engine": first}, times=1)
    )
    with xfft.config(faults=plan):
        xfft.fft2(x)
        fake_clock.now += 31.0
        xfft.fft2(x)  # probe succeeds, breaker closes
    assert resolve_call("fft2d", SHAPE).variant == first


def test_forced_variant_bypasses_ladder(rng):
    """A pinned engine is an explicit opinion: no injection, no failover."""
    x = _frame(rng)
    plan = FaultPlan(FaultSpec("engine.apply", mode="error"))
    with obs.capture() as trace, xfft.config(variant="stockham", faults=plan):
        _assert_parity(xfft.fft2(x), x)
    assert trace.select("resilience.fault") == []
    assert trace.select("resilience.failover") == []


def test_check_health_nan_fails_over(rng):
    first = _first_choice()
    x = _frame(rng)
    plan = FaultPlan(
        FaultSpec("engine.apply", mode="nan", match={"engine": first}, times=1)
    )
    with obs.capture() as trace, xfft.config(faults=plan, check_health="nan"):
        y = xfft.fft2(x)
    assert np.isfinite(np.asarray(y)).all()
    _assert_parity(y, x)
    (failover,) = trace.select("resilience.failover")
    assert failover["engine"] == first
    assert failover["reason"] == "nonfinite"
    assert failover["error"] is None


def test_health_guard_off_by_default(rng):
    first = _first_choice()
    x = _frame(rng)
    plan = FaultPlan(
        FaultSpec("engine.apply", mode="nan", match={"engine": first}, times=1)
    )
    with obs.capture() as trace, xfft.config(faults=plan):
        y = xfft.fft2(x)
    assert not np.isfinite(np.asarray(y)).all()  # poison passes through
    assert trace.select("resilience.failover") == []


def test_all_rungs_nonfinite_returns_last_output(rng):
    """When every rung yields non-finite values the input itself is
    poisoned: the guard returns the last output instead of raising."""
    x = _frame(rng)
    plan = FaultPlan(FaultSpec("engine.apply", mode="inf"))  # every engine
    with obs.capture() as trace, xfft.config(faults=plan, check_health="nan"):
        y = xfft.fft2(x)
    assert not np.isfinite(np.asarray(y)).all()
    failovers = trace.select("resilience.failover")
    assert len(failovers) >= 2          # walked more than one rung
    assert failovers[-1]["next"] is None  # and hit the bottom


def test_all_rungs_error_raises_last_error(rng):
    x = _frame(rng)
    plan = FaultPlan(FaultSpec("engine.apply", mode="error"))  # every engine
    with obs.capture() as trace, xfft.config(faults=plan):
        with pytest.raises(InjectedFault):
            xfft.fft2(x)
    assert trace.select("resilience.failover")[-1]["next"] is None
