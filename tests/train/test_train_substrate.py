"""Training substrate: optimizer, grad-accum equivalence, compression,
checkpoint exactness, fault-tolerant restart, data determinism."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore, save
from repro.configs.registry import smoke_config
from repro.data.pipeline import SyntheticLM, make_batch
from repro.models.build import build
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, cosine_schedule
from repro.optim.compression import compress_int8, compressed_mean, decompress_int8, init_error_state
from repro.train.loop import TrainLoop, TrainState, make_train_step


@pytest.fixture(scope="module")
def tiny():
    cfg = smoke_config("llama3.2-3b")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _batch(cfg, step=0, b=4, s=16):
    return make_batch(cfg, b, s, step)


def test_adamw_reduces_loss(tiny):
    cfg, model, params = tiny
    state = TrainState(params, adamw_init(params))
    step = jax.jit(make_train_step(model.loss_fn, peak_lr=1e-2, warmup=2, total=100))
    losses = []
    for i in range(12):
        state, m = step(state, _batch(cfg, 0))  # same batch -> should overfit
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses


def test_grad_accum_matches_big_batch(tiny):
    cfg, model, params = tiny
    b1 = _batch(cfg, 0, b=4)
    # accum=2 over two halves == one step over the full batch
    halves = jax.tree.map(lambda x: x.reshape(2, 2, *x.shape[1:]), b1)
    s_full = TrainState(params, adamw_init(params))
    s_acc = TrainState(params, adamw_init(params))
    step_full = jax.jit(make_train_step(model.loss_fn, accum=1, peak_lr=1e-3))
    step_acc = jax.jit(make_train_step(model.loss_fn, accum=2, peak_lr=1e-3))
    s_full, m_full = step_full(s_full, b1)
    s_acc, m_acc = step_acc(s_acc, halves)
    d = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), s_full.params, s_acc.params
    )
    assert max(jax.tree.leaves(d)) < 5e-5, m_acc


def test_cosine_schedule_shape():
    s = [float(cosine_schedule(jnp.asarray(i), peak_lr=1.0, warmup=10, total=100))
         for i in [0, 5, 10, 50, 100]]
    assert s[0] == 0.0 and s[1] == pytest.approx(0.5)
    assert s[2] == pytest.approx(1.0) and s[3] < 1.0 and s[4] >= 0.1 * 0.99


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, gn = clip_by_global_norm(g, max_norm=1.0)
    assert float(gn) == pytest.approx(np.sqrt(1000.0), rel=1e-5)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)


def test_int8_compression_roundtrip(rng):
    g = jnp.asarray(rng.standard_normal((128,)), jnp.float32)
    q, scale = compress_int8(g)
    deq = decompress_int8(q, scale)
    assert float(jnp.max(jnp.abs(deq - g))) <= float(scale) * 0.5 + 1e-6


def test_error_feedback_accumulates(rng):
    g = jnp.asarray(rng.standard_normal((64,)) * 1e-4, jnp.float32)  # tiny grads
    grads = {"w": g}
    err = init_error_state(grads)
    total = jnp.zeros_like(g)
    for _ in range(50):
        mean, err = compressed_mean(grads, err)
        total = total + mean["w"]
    # with error feedback the sum of quantised means tracks 50·g
    np.testing.assert_allclose(np.asarray(total), np.asarray(50 * g), rtol=0.05, atol=1e-4)


def test_compressed_training_converges(tiny):
    cfg, model, params = tiny
    state = TrainState(params, adamw_init(params))
    step = jax.jit(make_train_step(model.loss_fn, peak_lr=1e-2, compress=True))
    losses = []
    for i in range(12):
        state, m = step(state, _batch(cfg, 0))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses


def test_checkpoint_exact_roundtrip(tiny, tmp_path):
    cfg, model, params = tiny
    state = TrainState(params, adamw_init(params))
    save(str(tmp_path), 7, state.tree(), extra={"note": "x"})
    assert latest_step(str(tmp_path)) == 7
    restored = TrainState.from_tree(restore(str(tmp_path), 7, state.tree()))
    same = jax.tree.map(
        lambda a, b: bool((a == b).all()), state.params, restored.params
    )
    assert all(jax.tree.leaves(same))


def test_preemption_restart_is_bit_identical(tiny, tmp_path):
    """Kill at step 6, restart, and verify the final params match an
    uninterrupted run (data pipeline is (seed, step)-deterministic)."""
    cfg, model, _ = tiny

    def mk_loop(d):
        return TrainLoop(
            model, ckpt_dir=str(d), batch_fn=lambda s: _batch(cfg, s),
            save_every=3, peak_lr=1e-3,
        )

    # uninterrupted
    loop_a = mk_loop(tmp_path / "a")
    loop_a.run(jax.random.PRNGKey(0), 9)
    state_a, _ = loop_a.init_or_restore(jax.random.PRNGKey(0))

    # interrupted at 6 (checkpoint exists at 6), then resumed
    loop_b = mk_loop(tmp_path / "b")
    with pytest.raises(RuntimeError, match="simulated preemption"):
        loop_b.run(jax.random.PRNGKey(0), 9, fail_at=6)
    loop_b2 = mk_loop(tmp_path / "b")
    loop_b2.run(jax.random.PRNGKey(0), 9)
    state_b, start_b = loop_b2.init_or_restore(jax.random.PRNGKey(0))

    assert start_b == 9
    d = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), state_a.params, state_b.params
    )
    assert max(jax.tree.leaves(d)) == 0.0


def test_data_pipeline_deterministic():
    p = SyntheticLM(vocab=100, seq=32, batch=4, seed=3)
    a = np.asarray(p.batch_at(5)["tokens"])
    b = np.asarray(p.batch_at(5)["tokens"])
    c = np.asarray(p.batch_at(6)["tokens"])
    assert (a == b).all() and not (a == c).all()


def test_straggler_monitor_flags_slow_steps():
    from repro.train.loop import StragglerMonitor

    m = StragglerMonitor(threshold=2.0)
    for i in range(10):
        assert not m.record(i, 1.0)
    assert m.record(10, 5.0)
    assert m.flags and m.flags[0][0] == 10
