"""xfft.rfftn/irfftn vs numpy: the real N-D path never round-trips a real
array through a complex fftn (ROADMAP PR 3 follow-on)."""

import numpy as np
import pytest

import repro.xfft as xfft
from repro.plan import NORMS


def _real(rng, shape):
    return rng.standard_normal(shape).astype(np.float32)


def _close(got, want, scale=1.0):
    np.testing.assert_allclose(
        np.asarray(got), want, rtol=2e-3, atol=1e-2 * scale
    )


@pytest.mark.parametrize("norm", NORMS)
def test_rfftn_matches_numpy_3d(rng, norm):
    x = _real(rng, (8, 16, 32))
    _close(xfft.rfftn(x, norm=norm), np.fft.rfftn(x, norm=norm))


@pytest.mark.parametrize("norm", NORMS)
def test_irfftn_round_trips(rng, norm):
    x = _real(rng, (4, 8, 16))
    back = xfft.irfftn(xfft.rfftn(x, norm=norm), norm=norm)
    _close(back, x)


def test_rfftn_1d_and_2d_delegate_to_dedicated_kinds(rng):
    x = _real(rng, (16, 32))
    _close(xfft.rfftn(x, axes=(-1,)), np.fft.rfft(x))
    _close(xfft.rfftn(x), np.fft.rfftn(x))
    _close(xfft.irfftn(np.fft.rfftn(x).astype(np.complex64)), x)


def test_rfftn_s_crops_and_pads(rng):
    x = _real(rng, (8, 8, 8))
    want = np.fft.rfftn(x, s=(4, 16, 8), axes=(0, 1, 2))
    _close(xfft.rfftn(x, s=(4, 16, 8)), want)


def test_irfftn_recovers_odd_less_shapes(rng):
    x = _real(rng, (4, 8, 16))
    spec = np.fft.rfftn(x).astype(np.complex64)
    _close(xfft.irfftn(spec, s=x.shape), x)


def test_rfftn_rejects_complex_input(rng):
    z = (rng.standard_normal((8, 8, 8)) + 1j * rng.standard_normal((8, 8, 8))
         ).astype(np.complex64)
    with pytest.raises(TypeError, match="real input"):
        xfft.rfftn(z)


def test_rfftn_uses_real_kinds_not_complex_fftn(rng, monkeypatch):
    """The satellite's whole point: the innermost (largest) pass is the
    two-for-one real transform, and no full complex fftn ever runs."""
    import repro.xfft._transforms as _transforms
    from repro.plan.api import resolve_call as real_resolve_call

    kinds = []

    def spy(kind, shape, *args, **kwargs):
        kinds.append(kind)
        return real_resolve_call(kind, shape, *args, **kwargs)

    monkeypatch.setattr(_transforms, "resolve_call", spy)
    xfft.rfftn(_real(rng, (4, 8, 16)))
    assert kinds[0] == "rfft1d"            # real pass first, on the last axis
    assert set(kinds) == {"rfft1d", "fft1d"}
