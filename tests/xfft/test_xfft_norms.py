"""xfft vs numpy.fft: all eight transforms under every norm convention,
forward/inverse round-trips, axes= handling, and the named-axis errors.

This suite must stay DeprecationWarning-free (CI runs it with
``-W error::DeprecationWarning``): it exercises only the repro.xfft
surface, never the deprecated repro.core entry points.
"""

import jax.numpy as jnp
import numpy as np
import pytest

import repro.xfft as xfft

NORMS = ("backward", "ortho", "forward")


def _crand(rng, shape):
    return (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)).astype(
        np.complex64
    )


def _close(got, ref, atol=1e-4):
    got, ref = np.asarray(got), np.asarray(ref)
    scale = max(1.0, np.max(np.abs(ref)))
    np.testing.assert_allclose(got / scale, ref / scale, atol=atol)


@pytest.mark.parametrize("norm", NORMS)
def test_fft_ifft_match_numpy(rng, norm):
    z = _crand(rng, (3, 128))
    _close(xfft.fft(z, norm=norm), np.fft.fft(z, norm=norm))
    _close(xfft.ifft(z, norm=norm), np.fft.ifft(z, norm=norm))


@pytest.mark.parametrize("norm", NORMS)
def test_fft2_ifft2_match_numpy(rng, norm):
    z = _crand(rng, (2, 16, 32))
    _close(xfft.fft2(z, norm=norm), np.fft.fft2(z, norm=norm))
    _close(xfft.ifft2(z, norm=norm), np.fft.ifft2(z, norm=norm))


@pytest.mark.parametrize("norm", NORMS)
def test_rfft_irfft_match_numpy(rng, norm):
    x = rng.standard_normal((4, 64)).astype(np.float32)
    _close(xfft.rfft(x, norm=norm), np.fft.rfft(x, norm=norm))
    sp = np.fft.rfft(x).astype(np.complex64)
    _close(xfft.irfft(sp, norm=norm), np.fft.irfft(sp, norm=norm))


@pytest.mark.parametrize("norm", NORMS)
def test_rfft2_irfft2_match_numpy(rng, norm):
    x = rng.standard_normal((2, 16, 32)).astype(np.float32)
    _close(xfft.rfft2(x, norm=norm), np.fft.rfft2(x, norm=norm))
    sp = np.fft.rfft2(x).astype(np.complex64)
    _close(xfft.irfft2(sp, norm=norm), np.fft.irfft2(sp, norm=norm))


@pytest.mark.parametrize("norm", NORMS)
def test_roundtrips_under_every_norm(rng, norm):
    z = _crand(rng, (2, 64))
    _close(xfft.ifft(xfft.fft(z, norm=norm), norm=norm), z)
    f = _crand(rng, (8, 16))
    _close(xfft.ifft2(xfft.fft2(f, norm=norm), norm=norm), f)
    x = rng.standard_normal((3, 32)).astype(np.float32)
    _close(xfft.irfft(xfft.rfft(x, norm=norm), norm=norm), x)
    img = rng.standard_normal((16, 16)).astype(np.float32)
    _close(xfft.irfft2(xfft.rfft2(img, norm=norm), norm=norm), img)


def test_axes_and_n_arguments(rng):
    z = _crand(rng, (4, 8, 16))
    _close(xfft.fft(z, axis=0), np.fft.fft(z, axis=0))
    _close(xfft.fft(z, n=32, axis=-1), np.fft.fft(z, n=32, axis=-1))
    _close(xfft.fft2(z, axes=(0, 2)), np.fft.fft2(z, axes=(0, 2)))
    _close(xfft.ifft2(z, axes=(1, 0)), np.fft.ifft2(z, axes=(1, 0)))
    x = rng.standard_normal((4, 8, 16)).astype(np.float32)
    _close(xfft.rfft2(x, axes=(0, 1)), np.fft.rfft2(x, axes=(0, 1)))
    sp = np.fft.rfft(x, axis=1).astype(np.complex64)
    _close(xfft.irfft(sp, axis=1), np.fft.irfft(sp, axis=1))


def test_fftn_matches_numpy(rng):
    z = _crand(rng, (4, 8, 16))
    _close(xfft.fftn(z), np.fft.fftn(z))
    _close(xfft.fftn(z, norm="ortho"), np.fft.fftn(z, norm="ortho"))
    _close(xfft.ifftn(z, norm="forward"), np.fft.ifftn(z, norm="forward"))
    _close(xfft.fftn(z, axes=(1,)), np.fft.fftn(z, axes=(1,)))


def test_real_input_promoted(rng):
    x = rng.standard_normal((5, 64)).astype(np.float32)
    _close(xfft.fft(x), np.fft.fft(x))


def test_shifts_match_numpy_including_odd_lengths():
    a = np.arange(5 * 7).reshape(5, 7)
    np.testing.assert_array_equal(np.asarray(xfft.fftshift(a)), np.fft.fftshift(a))
    np.testing.assert_array_equal(np.asarray(xfft.ifftshift(a)), np.fft.ifftshift(a))
    np.testing.assert_array_equal(
        np.asarray(xfft.ifftshift(xfft.fftshift(a))), a
    )
    np.testing.assert_array_equal(
        np.asarray(xfft.fftshift(a, axes=1)), np.fft.fftshift(a, axes=1)
    )


def test_ifftshift2_inverts_fftshift2_odd_and_even():
    # exported from BOTH namespaces
    from repro.core import fftshift2, ifftshift2

    for shape in ((8, 8), (5, 7), (4, 9)):
        a = jnp.asarray(np.arange(shape[0] * shape[1]).reshape(shape))
        np.testing.assert_array_equal(np.asarray(ifftshift2(fftshift2(a))), a)
        np.testing.assert_array_equal(
            np.asarray(xfft.ifftshift2(xfft.fftshift2(a))), a
        )
        # 2D convenience == the general helper over the trailing axes
        np.testing.assert_array_equal(
            np.asarray(xfft.ifftshift2(a)),
            np.asarray(xfft.ifftshift(a, axes=(-2, -1))),
        )


def test_errors_name_axis_and_size():
    with pytest.raises(ValueError, match=r"axis 1 has length 96"):
        xfft.fft2(np.zeros((8, 96), np.float32))
    with pytest.raises(ValueError, match=r"axis 1 has length 12"):
        xfft.fft(np.zeros((2, 12), np.float32))
    with pytest.raises(ValueError, match=r"axis 0 has length 6"):
        xfft.rfft(np.zeros((6,), np.float32), axis=0)
    with pytest.raises(ValueError, match=r"axis 3 is out of bounds"):
        xfft.fft(np.zeros((2, 16), np.float32), axis=3)
    with pytest.raises(ValueError, match=r"name an axis twice"):
        xfft.fft2(np.zeros((8, 8), np.float32), axes=(1, -1))
    with pytest.raises(ValueError, match=r"s must have 2 entries"):
        xfft.irfft2(np.zeros((4, 5), np.complex64), s=(8,))
    with pytest.raises(ValueError, match=r"norm must be one of"):
        xfft.fft(np.zeros((2, 16), np.float32), norm="unitary")
    with pytest.raises(TypeError, match=r"rfft2 expects real input"):
        xfft.rfft2(np.zeros((8, 8), np.complex64))
