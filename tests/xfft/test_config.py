"""Context-scoped xfft configuration: scoping, plan-backed dispatch, and
composition with tuned plan wisdom.

Also DeprecationWarning-free by construction (CI enforces it): only the
repro.xfft surface and the planner are exercised.
"""

import numpy as np
import pytest

import repro.xfft as xfft
from repro.plan import (
    PlanCache,
    default_cache,
    plan_fft,
    problem_key,
    reset_default_cache,
)
from repro.plan.api import resolve_call


@pytest.fixture(autouse=True)
def _fresh_default_cache():
    reset_default_cache()
    yield
    reset_default_cache()


def test_config_scope_restores_on_exit():
    base = xfft.get_config()
    assert base.variant is None and base.mode == "estimate"
    with xfft.config(variant="radix4", mode="measure"):
        inner = xfft.get_config()
        assert inner.variant == "radix4" and inner.mode == "measure"
        with xfft.config(variant="stockham"):
            assert xfft.get_config().variant == "stockham"
            assert xfft.get_config().mode == "measure"  # inherited
        assert xfft.get_config().variant == "radix4"
    assert xfft.get_config() == base


def test_config_global_setter_and_restore():
    handle = xfft.config(variant="unrolled")
    try:
        assert xfft.get_config().variant == "unrolled"
    finally:
        handle.restore()
    assert xfft.get_config().variant is None
    handle.restore()  # second restore is a no-op, not an error


def test_config_auto_clears_outer_override():
    with xfft.config(variant="looped"):
        with xfft.config(variant="auto"):
            assert xfft.get_config().variant is None
        assert xfft.get_config().variant == "looped"


def test_config_rejects_bad_values():
    with pytest.raises(ValueError, match="unknown variant"):
        xfft.config(variant="fastest")
    with pytest.raises(ValueError, match="mode must be"):
        xfft.config(mode="exhaustive")
    with pytest.raises(ValueError, match="precision"):
        xfft.config(precision="bfloat16")


def test_rfft2_with_no_kwargs_resolves_through_plan(rng):
    """The ISSUE 3 acceptance gate: a bare xfft call consults AND
    populates the plan cache."""
    cache = default_cache()
    assert len(cache) == 0
    x = rng.standard_normal((32, 32)).astype(np.float32)
    got = np.asarray(xfft.rfft2(x))
    np.testing.assert_allclose(got, np.fft.rfft2(x), atol=1e-3)
    key = problem_key("rfft2d", (32, 32), dtype="float32")
    plan = cache.get(key)
    assert plan is not None and plan.variant is not None
    assert cache.misses >= 1  # the resolve consulted the cache first
    before_hits = cache.hits
    np.asarray(xfft.rfft2(x))  # second call: pure cache hit
    assert cache.hits > before_hits and len(cache) == 1


def test_variant_override_dispatches_only_inside_scope(rng, monkeypatch):
    """config(variant="fused_r4") must reroute dispatch to the Pallas
    kernel inside the scope and nowhere else."""
    import repro.kernels.ops as ops

    calls = []
    real_kernel = ops.rfft2_kernel

    def spy(x, **kw):
        calls.append(np.asarray(x).shape)
        return real_kernel(x, **kw)

    monkeypatch.setattr(ops, "rfft2_kernel", spy)
    x = rng.standard_normal((16, 16)).astype(np.float32)
    ref = np.fft.rfft2(x)

    np.testing.assert_allclose(np.asarray(xfft.rfft2(x)), ref, atol=1e-3)
    assert calls == []  # ESTIMATE on CPU never picks the interpret kernel
    with xfft.config(variant="fused_r4"):
        np.testing.assert_allclose(np.asarray(xfft.rfft2(x)), ref, atol=1e-3)
    assert len(calls) == 1  # forced exactly once, inside the scope
    np.asarray(xfft.rfft2(x))
    assert len(calls) == 1  # override did not leak past the scope


def test_forced_variant_does_not_pollute_wisdom(rng):
    x = rng.standard_normal((16, 16)).astype(np.float32)
    np.asarray(xfft.rfft2(x))  # plans + caches the default schedule
    key = problem_key("rfft2d", (16, 16), dtype="float32")
    planned = default_cache().get(key).variant
    with xfft.config(variant="looped"):
        np.asarray(xfft.rfft2(x))
    assert default_cache().get(key).variant == planned  # wisdom untouched


def test_config_composes_with_measure_wisdom(rng):
    """Tuned wisdom steers default dispatch; a scoped override wins inside
    its scope; the wisdom is back in charge after exit."""
    cache = PlanCache()
    tuned = plan_fft("fft2d", (16, 16), mode="measure", cache=cache,
                     measure_iters=1)
    hit = resolve_call("fft2d", (16, 16), cache=cache)
    assert hit is cache.get(tuned.key) and hit.mode == "measure"
    other = next(v for v in ("stockham", "radix4") if v != tuned.variant)
    with xfft.config(variant=other):
        forced = resolve_call("fft2d", (16, 16), cache=cache)
        assert forced.variant == other and forced.mode == "forced"
    again = resolve_call("fft2d", (16, 16), cache=cache)
    assert again is cache.get(tuned.key)  # wisdom restored, not re-tuned


def test_cache_dir_scopes_wisdom_location(rng, tmp_path):
    from repro.plan.api import _cache_for_dir

    x = rng.standard_normal((8, 8)).astype(np.float32)
    with xfft.config(cache_dir=str(tmp_path)):
        np.asarray(xfft.rfft2(x))
    key = problem_key("rfft2d", (8, 8), dtype="float32")
    # the scoped call went to the directory cache, not the default one
    assert _cache_for_dir(str(tmp_path)).get(key) is not None
    assert default_cache().get(key) is None
    # ESTIMATE plans stay in memory; only MEASURE results earn a file write
    # (see test_measure_mode_upgrades_cache_misses)
    assert not (tmp_path / "xfft_plans.json").exists()


def test_measure_mode_upgrades_cache_misses(rng, tmp_path):
    x = rng.standard_normal((8, 8)).astype(np.float32)
    with xfft.config(mode="measure", cache_dir=str(tmp_path)):
        np.asarray(xfft.rfft2(x))
    fresh = PlanCache(path=str(tmp_path / "xfft_plans.json"))
    plan = fresh.get(problem_key("rfft2d", (8, 8), dtype="float32"))
    assert plan is not None and plan.mode == "measure"
    assert plan.measured_us is not None
