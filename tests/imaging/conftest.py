"""Shared imaging fixtures."""

import numpy as np
import pytest


@pytest.fixture
def natural_image():
    """A frame with the statistics that produce the cross artifact: a
    strong non-periodic ramp (opposite borders mismatch) plus texture."""
    rng = np.random.default_rng(7)
    i, j = np.mgrid[0:64, 0:128]
    return (0.05 * i + 0.03 * j + 0.2 * rng.standard_normal((64, 128))).astype(
        np.float32
    )
