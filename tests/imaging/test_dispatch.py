"""The ISSUE 4 acceptance gate: every imaging op resolves its transforms
through repro.plan (spy on resolve_call; forced-dispatch reroutes), and
the whole surface is DeprecationWarning-free (no legacy core shims)."""

import warnings

import numpy as np
import pytest

import repro.imaging.tiled as tiled
import repro.xfft as xfft
import repro.xfft._transforms as _transforms
from repro.imaging import (
    apply_shift,
    fft2_psd,
    fftconv2,
    image_to_kspace,
    kspace_to_image,
    matched_filter2,
    oaconvolve2,
    psd_decompose,
    register_phase_correlation,
)
from repro.plan.api import resolve_call as _real_resolve_call


@pytest.fixture
def plan_calls(monkeypatch):
    """Record every planner resolution made by the xfft front door and
    the imaging tile picker; error on any DeprecationWarning (legacy
    ``repro.core`` shims would emit one)."""
    calls = []

    def spy(kind, shape, *args, **kwargs):
        calls.append(kind)
        return _real_resolve_call(kind, shape, *args, **kwargs)

    monkeypatch.setattr(_transforms, "resolve_call", spy)
    monkeypatch.setattr(tiled, "resolve_call", spy)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        yield calls


@pytest.fixture
def frame(rng):
    return rng.standard_normal((32, 32)).astype(np.float32)


def test_psd_resolves_through_plan(plan_calls, frame):
    # real frames ride the two-for-one route end to end: rfft borders,
    # rfft2/irfft2 body — no full complex transform anywhere
    psd_decompose(frame)
    assert "rfft1d" in plan_calls and "rfft2d" in plan_calls
    assert "fft1d" not in plan_calls and "fft2d" not in plan_calls
    plan_calls.clear()
    fft2_psd(frame)
    assert plan_calls.count("rfft1d") == 2 and "rfft2d" in plan_calls
    assert "fft2d" not in plan_calls
    plan_calls.clear()
    fft2_psd(frame.astype(np.complex64))     # complex path unchanged
    assert plan_calls.count("fft1d") == 2 and "fft2d" in plan_calls


def test_registration_resolves_through_plan(plan_calls, frame):
    register_phase_correlation(frame, frame[::-1].copy(), upsample_factor=4)
    assert plan_calls.count("rfft2d") == 3  # two forward + one inverse
    plan_calls.clear()
    apply_shift(frame, (1.0, 2.0))
    assert plan_calls.count("rfft2d") == 2


def test_kspace_resolves_through_plan(plan_calls, frame):
    kspace_to_image(image_to_kspace(frame))
    assert plan_calls.count("fft2d") == 2


def test_convolution_resolves_through_plan(plan_calls, rng, frame):
    kernel = rng.standard_normal((5, 5)).astype(np.float32)
    oaconvolve2(frame, kernel)
    assert plan_calls[0] == "oaconv2d"       # the tile itself is planned
    assert "rfft2d" in plan_calls            # per-tile transforms follow
    plan_calls.clear()
    fftconv2(frame, kernel)
    assert plan_calls.count("rfft2d") == 3
    plan_calls.clear()
    matched_filter2(frame, kernel, tile=(16, 16))
    assert "rfft2d" in plan_calls and "oaconv2d" not in plan_calls  # tile pinned


def test_forced_dispatch_reaches_imaging_ops(rng, monkeypatch):
    """A scoped variant override must reroute the transforms INSIDE the
    imaging ops — proof their FFTs go through resolve_call, not around it."""
    import repro.kernels.ops as ops

    kernel_calls = []
    real_kernel = ops.rfft2_kernel

    def spy(x, **kw):
        kernel_calls.append(np.asarray(x).shape)
        return real_kernel(x, **kw)

    monkeypatch.setattr(ops, "rfft2_kernel", spy)
    frame = rng.standard_normal((16, 16)).astype(np.float32)
    apply_shift(frame, (1.0, 0.0))
    assert kernel_calls == []                # ESTIMATE on CPU: jnp engines
    with xfft.config(variant="fused_r4"):
        apply_shift(frame, (1.0, 0.0))
    assert len(kernel_calls) == 1            # forced, exactly once, in scope
    apply_shift(frame, (1.0, 0.0))
    assert len(kernel_calls) == 1            # nothing leaked past the scope
