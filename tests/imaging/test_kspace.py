"""Centered k-space operators: round trip, DC centering, unitarity."""

import numpy as np
import pytest

from repro.imaging import image_to_kspace, kspace_to_image


def complex_frame(rng, shape):
    return (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)).astype(
        np.complex64
    )


def test_round_trip_is_identity(rng):
    x = complex_frame(rng, (32, 64))
    back = np.asarray(kspace_to_image(image_to_kspace(x)))
    np.testing.assert_allclose(back, x, atol=1e-4)


def test_dc_lands_at_array_centre():
    const = np.ones((16, 16), np.float32)
    k = np.abs(np.asarray(image_to_kspace(const)))
    assert np.unravel_index(k.argmax(), k.shape) == (8, 8)
    assert k.sum() == pytest.approx(k[8, 8])  # a constant is pure DC


def test_ortho_norm_preserves_energy(rng):
    x = complex_frame(rng, (32, 32))
    k = np.asarray(image_to_kspace(x))
    assert np.linalg.norm(k) == pytest.approx(np.linalg.norm(x), rel=1e-4)


def test_matches_numpy_centered_convention(rng):
    """The moco-workshop spelling, verbatim in numpy, is the oracle."""
    x = complex_frame(rng, (16, 32))
    want = np.fft.fftshift(np.fft.fft2(np.fft.ifftshift(x), norm="ortho"))
    np.testing.assert_allclose(np.asarray(image_to_kspace(x)), want, atol=1e-4)
    want_inv = np.fft.fftshift(np.fft.ifft2(np.fft.ifftshift(x), norm="ortho"))
    np.testing.assert_allclose(np.asarray(kspace_to_image(x)), want_inv, atol=1e-4)


def test_batched_leading_axes(rng):
    frames = complex_frame(rng, (3, 2, 16, 16))  # e.g. (coil, frame, H, W)
    k = np.asarray(image_to_kspace(frames))
    assert k.shape == frames.shape
    np.testing.assert_allclose(
        k[1, 0], np.asarray(image_to_kspace(frames[1, 0])), atol=1e-5
    )


def test_alternate_axes(rng):
    x = complex_frame(rng, (16, 4, 32))
    k = np.asarray(image_to_kspace(x, axes=(0, 2)))
    want = np.stack(
        [np.asarray(image_to_kspace(x[:, c, :])) for c in range(4)], axis=1
    )
    np.testing.assert_allclose(k, want, atol=1e-5)
