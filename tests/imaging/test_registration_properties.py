"""Hypothesis property tests: phase correlation recovers random shifts
(whole-pixel exactly, half-pixel to the upsampling grid).

Guarded with importorskip: hypothesis is a test extra, not a runtime
dependency."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from _helpers import smooth_image  # noqa: E402

from repro.imaging import apply_shift, register_phase_correlation  # noqa: E402


@settings(max_examples=30, deadline=None)
@given(
    st.integers(min_value=-31, max_value=31),
    st.integers(min_value=-31, max_value=31),
    st.integers(min_value=0, max_value=50),
)
def test_integer_shifts_recover_exactly(dy, dx, seed):
    ref = smooth_image(64, seed=seed)
    mov = np.asarray(apply_shift(ref, (float(dy), float(dx))))
    got = np.asarray(register_phase_correlation(ref, mov))
    np.testing.assert_array_equal(got, [-dy, -dx])


@settings(max_examples=20, deadline=None)
@given(
    st.integers(min_value=-15, max_value=15),
    st.integers(min_value=-15, max_value=15),
    st.integers(min_value=0, max_value=50),
)
def test_half_pixel_shifts_recover_with_upsampling(ty, tx, seed):
    dy, dx = ty / 2.0, tx / 2.0
    ref = smooth_image(64, seed=seed)
    mov = np.asarray(apply_shift(ref, (dy, dx)))
    got = np.asarray(register_phase_correlation(ref, mov, upsample_factor=4))
    np.testing.assert_allclose(got, [-dy, -dx], atol=0.25 + 1e-6)
