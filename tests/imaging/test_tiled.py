"""Overlap-save tiled convolution vs the single-transform reference and
a size-exact numpy oracle — including the oversized-input acceptance
gate (frames ≥ 4× the largest single fused transform)."""

import numpy as np
import pytest
from _helpers import conv2_full_oracle, crop_oracle

from repro.imaging import fftconv2, matched_filter2, oaconvolve2
from repro.kernels.ops import fft2_fits_budget


@pytest.mark.parametrize("mode", ["full", "same", "valid"])
def test_oaconvolve2_matches_oracle_all_modes(rng, mode):
    image = rng.standard_normal((48, 80)).astype(np.float32)
    kernel = rng.standard_normal((7, 5)).astype(np.float32)
    oracle = crop_oracle(conv2_full_oracle(image, kernel), 48, 80, 7, 5, mode)
    np.testing.assert_allclose(
        np.asarray(oaconvolve2(image, kernel, mode=mode, tile=(16, 16))),
        oracle,
        atol=1e-3,
    )
    np.testing.assert_allclose(
        np.asarray(fftconv2(image, kernel, mode=mode)), oracle, atol=1e-3
    )


def test_oaconvolve2_matches_fftconv2_plan_picked_tile(rng):
    image = rng.standard_normal((64, 64)).astype(np.float32)
    kernel = rng.standard_normal((9, 9)).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(oaconvolve2(image, kernel)),       # planner picks the tile
        np.asarray(fftconv2(image, kernel, mode="same")),
        atol=1e-3,
    )


def test_even_kernel_same_mode_offsets(rng):
    image = rng.standard_normal((32, 32)).astype(np.float32)
    kernel = rng.standard_normal((4, 6)).astype(np.float32)
    oracle = crop_oracle(conv2_full_oracle(image, kernel), 32, 32, 4, 6, "same")
    np.testing.assert_allclose(
        np.asarray(oaconvolve2(image, kernel, tile=(16, 16))), oracle, atol=1e-3
    )


def test_complex_operands(rng):
    image = (rng.standard_normal((32, 48)) + 1j * rng.standard_normal((32, 48))
             ).astype(np.complex64)
    kernel = (rng.standard_normal((5, 4)) + 1j * rng.standard_normal((5, 4))
              ).astype(np.complex64)
    oracle = conv2_full_oracle(image, kernel)
    got = np.asarray(oaconvolve2(image, kernel, mode="full", tile=(16, 16)))
    np.testing.assert_allclose(got, oracle, atol=1e-3)


def test_batched_images_and_per_item_kernels(rng):
    images = rng.standard_normal((3, 24, 24)).astype(np.float32)
    kernels = rng.standard_normal((3, 5, 5)).astype(np.float32)
    got = np.asarray(oaconvolve2(images, kernels, mode="same", tile=(16, 16)))
    for b in range(3):
        oracle = crop_oracle(
            conv2_full_oracle(images[b], kernels[b]), 24, 24, 5, 5, "same"
        )
        np.testing.assert_allclose(got[b], oracle, atol=1e-3)


def test_oversized_input_matches_fftconv_acceptance(rng):
    """The ISSUE 4 acceptance gate: an input whose working set is >= 4x
    the largest single fused transform still matches the one-shot
    spectral convolution to fp32 tolerance."""
    h = w = 1024
    # 512^2 is the largest real frame the fused census admits; the input
    # is 4x that, and the padded single transform would be 2048^2.
    assert fft2_fits_budget(512, 512, real=True)
    assert not fft2_fits_budget(1024, 512, real=True)
    image = rng.standard_normal((h, w)).astype(np.float32)
    kernel = rng.standard_normal((17, 17)).astype(np.float32)
    got = np.asarray(oaconvolve2(image, kernel, mode="same"))
    oracle = crop_oracle(conv2_full_oracle(image, kernel), h, w, 17, 17, "same")
    scale = np.abs(oracle).max()
    np.testing.assert_allclose(got, oracle, atol=2e-3 * scale)


def test_matched_filter_locates_template(rng):
    scene = 0.1 * rng.standard_normal((96, 96)).astype(np.float32)
    template = np.zeros((8, 8), np.float32)
    template[3:5, :] = 1.0
    template[:, 3:5] = 1.0
    scene[40:48, 60:68] += template
    corr = np.asarray(matched_filter2(scene, template, tile=(32, 32)))
    peak = np.unravel_index(corr.argmax(), corr.shape)
    # peak lands at the template's centre (same-mode correlation)
    assert abs(peak[0] - 43.5) <= 1 and abs(peak[1] - 63.5) <= 1


def test_single_tile_falls_back_to_one_transform(rng):
    image = rng.standard_normal((8, 8)).astype(np.float32)
    kernel = rng.standard_normal((3, 3)).astype(np.float32)
    got = np.asarray(oaconvolve2(image, kernel, mode="full", tile=(64, 64)))
    np.testing.assert_allclose(
        got, conv2_full_oracle(image, kernel), atol=1e-4
    )


def test_bad_arguments_rejected(rng):
    image = rng.standard_normal((16, 16)).astype(np.float32)
    kernel = rng.standard_normal((5, 5)).astype(np.float32)
    with pytest.raises(ValueError, match="smaller than kernel"):
        oaconvolve2(image, kernel, tile=(4, 16))
    with pytest.raises(ValueError, match="mode"):
        oaconvolve2(image, kernel, mode="reflect", tile=(16, 16))
    with pytest.raises(ValueError, match="valid-mode"):
        fftconv2(kernel, image, mode="valid")  # kernel bigger than image
    with pytest.raises(ValueError, match="image and"):
        oaconvolve2(image, np.float32(1.0))
