"""Hypothesis property tests: overlap-save equals the one-shot spectral
convolution for random geometries, tiles and modes.

Guarded with importorskip: hypothesis is a test extra, not a runtime
dependency."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from _helpers import conv2_full_oracle, crop_oracle  # noqa: E402

from repro.imaging import oaconvolve2  # noqa: E402

geometry = st.tuples(
    st.integers(min_value=8, max_value=48),    # image H
    st.integers(min_value=8, max_value=48),    # image W
    st.integers(min_value=1, max_value=7),     # kernel KH
    st.integers(min_value=1, max_value=7),     # kernel KW
    st.integers(min_value=3, max_value=6),     # log2 tile H
    st.integers(min_value=3, max_value=6),     # log2 tile W
    st.sampled_from(["full", "same", "valid"]),
    st.integers(min_value=0, max_value=2**31 - 1),  # seed
)


@settings(max_examples=40, deadline=None)
@given(geometry)
def test_oaconvolve2_matches_oracle_on_random_geometry(params):
    h, w, kh, kw, lth, ltw, mode, seed = params
    th, tw = 1 << lth, 1 << ltw
    if th < kh or tw < kw:
        th, tw = max(th, 1 << (kh - 1).bit_length()), max(tw, 1 << (kw - 1).bit_length())
    rng = np.random.default_rng(seed)
    image = rng.standard_normal((h, w)).astype(np.float32)
    kernel = rng.standard_normal((kh, kw)).astype(np.float32)
    oracle = crop_oracle(conv2_full_oracle(image, kernel), h, w, kh, kw, mode)
    got = np.asarray(oaconvolve2(image, kernel, mode=mode, tile=(th, tw)))
    assert got.shape == oracle.shape
    scale = max(np.abs(oracle).max(), 1.0)
    np.testing.assert_allclose(got, oracle, atol=2e-4 * scale)


@settings(max_examples=15, deadline=None)
@given(
    st.integers(min_value=8, max_value=32),
    st.integers(min_value=1, max_value=5),
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_planner_tile_agrees_with_pinned_tiles(n, k, seed):
    """Whatever tile the planner picks, the numbers match a pinned tile."""
    rng = np.random.default_rng(seed)
    image = rng.standard_normal((n, n)).astype(np.float32)
    kernel = rng.standard_normal((k, k)).astype(np.float32)
    auto = np.asarray(oaconvolve2(image, kernel, mode="same"))
    pinned = np.asarray(oaconvolve2(image, kernel, mode="same", tile=(8, 8)))
    scale = max(np.abs(pinned).max(), 1.0)
    np.testing.assert_allclose(auto, pinned, atol=2e-4 * scale)
