"""Periodic-plus-smooth decomposition: exact split, matching borders,
in-spectrum solve consistency, and the edge-artifact acceptance gate."""

import numpy as np
import pytest

from repro.imaging import fft2_psd, psd_decompose


def cross_energy_ratio(spectrum: np.ndarray) -> float:
    """Energy on the spectrum's axis lines (the cross artifact's home)
    relative to total AC energy."""
    power = np.abs(spectrum) ** 2
    total = power.sum() - power[..., 0, 0]
    cross = power[..., 0, 1:].sum() + power[..., 1:, 0].sum()
    return float(cross / total)


def test_decomposition_is_exact(natural_image):
    periodic, smooth = psd_decompose(natural_image)
    np.testing.assert_allclose(
        np.asarray(periodic) + np.asarray(smooth), natural_image, atol=1e-4
    )


def test_periodic_component_borders_match(natural_image):
    periodic = np.asarray(psd_decompose(natural_image)[0])
    orig_mismatch = np.abs(natural_image[0] - natural_image[-1]).mean()
    new_mismatch = np.abs(periodic[0] - periodic[-1]).mean()
    assert new_mismatch < 0.1 * orig_mismatch
    orig_mismatch = np.abs(natural_image[:, 0] - natural_image[:, -1]).mean()
    new_mismatch = np.abs(periodic[:, 0] - periodic[:, -1]).mean()
    assert new_mismatch < 0.1 * orig_mismatch


def test_in_spectrum_solve_matches_explicit_decomposition(natural_image):
    """fft2_psd must equal fft2 of the explicitly decomposed periodic
    component: the two 1D border FFTs solve the same Poisson problem."""
    periodic, _ = psd_decompose(natural_image)
    got = np.asarray(fft2_psd(natural_image))
    want = np.fft.fft2(np.asarray(periodic))
    np.testing.assert_allclose(got, want, atol=2e-3 * np.abs(want).max())


def test_no_cross_artifact_on_natural_image(natural_image):
    """The ISSUE 4 acceptance gate: the periodic spectrum's border energy
    collapses relative to plain fft2 on a natural-image fixture."""
    plain = cross_energy_ratio(np.fft.fft2(natural_image))
    psd = cross_energy_ratio(np.asarray(fft2_psd(natural_image)))
    assert psd < 0.05 * plain, (psd, plain)


def test_matching_borders_give_zero_smooth_part():
    """The smooth component is driven ONLY by the border mismatch: an
    image whose opposite borders agree decomposes to smooth == 0."""
    i, j = np.mgrid[0:32, 0:32]
    # period 31 = H-1, so row 0 equals row 31 and col 0 equals col 31
    tile = np.sin(2 * np.pi * 3 * i / 31) * np.cos(2 * np.pi * 5 * j / 31)
    tile = tile.astype(np.float32)
    np.testing.assert_allclose(tile[0], tile[-1], atol=1e-6)
    _, smooth = psd_decompose(tile)
    assert np.abs(np.asarray(smooth)).max() < 1e-4


def test_batched_and_moved_axes(natural_image):
    batch = np.stack([natural_image, natural_image[::-1]])
    periodic, smooth = psd_decompose(batch)
    assert periodic.shape == batch.shape
    p0 = np.asarray(psd_decompose(batch[1])[0])
    np.testing.assert_allclose(np.asarray(periodic)[1], p0, atol=1e-4)
    # channels-last layout via axes=
    moved = np.moveaxis(batch, 0, -1)
    pm, _ = psd_decompose(moved, axes=(0, 1))
    np.testing.assert_allclose(
        np.moveaxis(np.asarray(pm), -1, 0), np.asarray(periodic), atol=1e-4
    )


def test_out_of_bounds_axes_rejected(natural_image):
    """Same axes contract as xfft.fft2: a typo'd axis raises, never wraps."""
    with pytest.raises(ValueError, match="out of bounds"):
        psd_decompose(natural_image, axes=(0, 5))
    with pytest.raises(ValueError, match="twice"):
        fft2_psd(natural_image, axes=(0, 0))


def test_fft2_psd_norm_conventions(natural_image):
    base = np.asarray(fft2_psd(natural_image))
    n = natural_image.size
    np.testing.assert_allclose(
        np.asarray(fft2_psd(natural_image, norm="ortho")),
        base / np.sqrt(n),
        atol=1e-3,
    )
    np.testing.assert_allclose(
        np.asarray(fft2_psd(natural_image, norm="forward")), base / n, atol=1e-4
    )
    with pytest.raises(ValueError, match="norm"):
        fft2_psd(natural_image, norm="unitary")


def test_real_path_matches_complex_path(natural_image):
    """The two-for-one real route (rfft borders + rfft2 body + Hermitian
    expansion) must agree with the complex route under every norm."""
    for norm in (None, "ortho", "forward"):
        got = np.asarray(fft2_psd(natural_image, norm=norm))
        want = np.asarray(
            fft2_psd(natural_image.astype(np.complex64), norm=norm)
        )
        np.testing.assert_allclose(got, want, atol=1e-4 * np.abs(want).max())


def test_real_decompose_matches_complex_and_stays_real(natural_image):
    p_r, s_r = (np.asarray(a) for a in psd_decompose(natural_image))
    assert p_r.dtype == np.float32 and s_r.dtype == np.float32
    p_c, s_c = (
        np.asarray(a) for a in psd_decompose(natural_image.astype(np.complex64))
    )
    np.testing.assert_allclose(p_r, p_c.real, atol=1e-4)
    np.testing.assert_allclose(s_r, s_c.real, atol=1e-4)


def test_complex_input_supported(rng):
    z = (rng.standard_normal((32, 32)) + 1j * rng.standard_normal((32, 32))).astype(
        np.complex64
    )
    periodic, smooth = psd_decompose(z)
    np.testing.assert_allclose(
        np.asarray(periodic) + np.asarray(smooth), z, atol=1e-4
    )
