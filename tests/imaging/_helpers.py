"""Shared imaging test helpers (numpy-only; the code under test is jax)."""

import numpy as np

# The one band-limited frame generator, shared with benchmarks and other
# test trees (subpixel shifts are well posed on its output).
from repro.imaging.synthetic import band_limited_frame as smooth_image

__all__ = ["smooth_image", "conv2_full_oracle", "crop_oracle"]


def conv2_full_oracle(image: np.ndarray, kernel: np.ndarray) -> np.ndarray:
    """Full linear 2D convolution via numpy's (size-exact) FFT."""
    fh = image.shape[-2] + kernel.shape[-2] - 1
    fw = image.shape[-1] + kernel.shape[-1] - 1
    if np.iscomplexobj(image) or np.iscomplexobj(kernel):
        return np.fft.ifft2(
            np.fft.fft2(image, s=(fh, fw)) * np.fft.fft2(kernel, s=(fh, fw))
        )
    return np.fft.irfft2(
        np.fft.rfft2(image, s=(fh, fw)) * np.fft.rfft2(kernel, s=(fh, fw)),
        s=(fh, fw),
    )


def crop_oracle(full: np.ndarray, h: int, w: int, kh: int, kw: int, mode: str):
    """Crop a full conv oracle to scipy's mode conventions (matching
    repro.imaging.tiled._crop_mode)."""
    if mode == "full":
        return full
    if mode == "same":
        top, left = (kh - 1) // 2, (kw - 1) // 2
        return full[..., top:top + h, left:left + w]
    return full[..., kh - 1:h, kw - 1:w]
