"""Phase-correlation registration: whole-pixel recovery (incl. wraps and
odd shifts), subpixel refinement, batching, and the shift operator."""

import numpy as np
import pytest

from _helpers import smooth_image

from repro.imaging import apply_shift, register_phase_correlation


@pytest.mark.parametrize("shift", [(0, 0), (5, 9), (-7, 3), (31, -17), (1, -1)])
def test_whole_pixel_shifts_recovered(shift):
    ref = smooth_image(64, seed=3)
    mov = np.asarray(apply_shift(ref, np.asarray(shift, np.float32)))
    got = np.asarray(register_phase_correlation(ref, mov))
    np.testing.assert_array_equal(got, [-shift[0], -shift[1]])


def test_registration_round_trip_realigns():
    ref = smooth_image(64, seed=4)
    mov = np.asarray(apply_shift(ref, (11.0, -6.0)))
    shift = register_phase_correlation(ref, mov)
    back = np.asarray(apply_shift(mov, shift))
    np.testing.assert_allclose(back, ref, atol=1e-4)


@pytest.mark.parametrize(
    "shift", [(2.5, -1.25), (-3.75, 4.5), (0.25, 0.75), (7.5, -0.5)]
)
def test_subpixel_shifts_recovered(shift):
    """Odd (non-integer) shifts: the upsampled-DFT refinement resolves
    quarter-pixel displacements on a band-limited frame."""
    ref = smooth_image(64, seed=5)
    mov = np.asarray(apply_shift(ref, np.asarray(shift, np.float32)))
    got = np.asarray(register_phase_correlation(ref, mov, upsample_factor=8))
    np.testing.assert_allclose(got, [-shift[0], -shift[1]], atol=1 / 8 + 1e-6)


def test_subpixel_precision_scales_with_upsampling():
    ref = smooth_image(64, seed=6)
    mov = np.asarray(apply_shift(ref, (1.3, -2.6)))
    got = np.asarray(register_phase_correlation(ref, mov, upsample_factor=20))
    np.testing.assert_allclose(got, [-1.3, 2.6], atol=0.06)


def test_batched_registration_one_call():
    ref = smooth_image(32, seed=7)
    shifts = [(1.0, 2.0), (3.0, -4.0), (-5.0, 0.0)]
    movs = np.stack([np.asarray(apply_shift(ref, s)) for s in shifts])
    refs = np.broadcast_to(ref, movs.shape)
    got = np.asarray(register_phase_correlation(refs, movs))
    np.testing.assert_array_equal(got, [[-a, -b] for a, b in shifts])


def test_complex_frames_register():
    rng = np.random.default_rng(8)
    base = smooth_image(32, seed=9) + 1j * smooth_image(32, seed=10)
    ref = base.astype(np.complex64)
    mov = np.asarray(apply_shift(ref, (4.0, -3.0)))
    got = np.asarray(register_phase_correlation(ref, mov))
    np.testing.assert_array_equal(got, [-4.0, 3.0])
    del rng


def test_apply_shift_integer_matches_roll():
    rng = np.random.default_rng(11)
    x = rng.standard_normal((16, 32)).astype(np.float32)
    got = np.asarray(apply_shift(x, (3.0, -5.0)))
    np.testing.assert_allclose(got, np.roll(x, (3, -5), axis=(0, 1)), atol=1e-4)


def test_apply_shift_batched_per_frame_shifts():
    rng = np.random.default_rng(12)
    x = rng.standard_normal((2, 16, 16)).astype(np.float32)
    shifts = np.asarray([[1.0, 2.0], [-3.0, 4.0]], np.float32)
    got = np.asarray(apply_shift(x, shifts))
    for k in range(2):
        np.testing.assert_allclose(
            got[k],
            np.roll(x[k], tuple(shifts[k].astype(int)), axis=(0, 1)),
            atol=1e-4,
        )


def test_shape_mismatch_rejected():
    with pytest.raises(ValueError, match="share a shape"):
        register_phase_correlation(np.zeros((8, 8)), np.zeros((8, 16)))
    with pytest.raises(ValueError, match="dy, dx"):
        apply_shift(np.zeros((8, 8), np.float32), (1.0, 2.0, 3.0))
