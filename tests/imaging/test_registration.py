"""Phase-correlation registration: whole-pixel recovery (incl. wraps and
odd shifts), subpixel refinement, batching, the shift operator, and the
log-polar (Fourier-Mellin) rotation+scale estimator."""

import math

import numpy as np
import pytest

from _helpers import smooth_image

from repro.imaging import apply_shift, register_phase_correlation
from repro.imaging.registration import register_logpolar


def rotate_scale(img: np.ndarray, angle: float, scale: float) -> np.ndarray:
    """Warp ``img`` so the output looks like ``img`` rotated by ``angle``
    (counter-clockwise, y-up) and magnified by ``scale`` about the
    centre — the convention register_logpolar reports."""
    from jax.scipy.ndimage import map_coordinates

    h, w = img.shape
    yy, xx = np.mgrid[0:h, 0:w].astype(np.float64)
    dy, dx = yy - h / 2, xx - w / 2
    ca, sa = math.cos(angle), math.sin(angle)
    src_c = (ca * dx - sa * dy) / scale + w / 2        # inverse mapping
    src_r = (sa * dx + ca * dy) / scale + h / 2
    return np.asarray(
        map_coordinates(img, [src_r, src_c], order=1, mode="constant")
    )


@pytest.mark.parametrize("shift", [(0, 0), (5, 9), (-7, 3), (31, -17), (1, -1)])
def test_whole_pixel_shifts_recovered(shift):
    ref = smooth_image(64, seed=3)
    mov = np.asarray(apply_shift(ref, np.asarray(shift, np.float32)))
    got = np.asarray(register_phase_correlation(ref, mov))
    np.testing.assert_array_equal(got, [-shift[0], -shift[1]])


def test_registration_round_trip_realigns():
    ref = smooth_image(64, seed=4)
    mov = np.asarray(apply_shift(ref, (11.0, -6.0)))
    shift = register_phase_correlation(ref, mov)
    back = np.asarray(apply_shift(mov, shift))
    np.testing.assert_allclose(back, ref, atol=1e-4)


@pytest.mark.parametrize(
    "shift", [(2.5, -1.25), (-3.75, 4.5), (0.25, 0.75), (7.5, -0.5)]
)
def test_subpixel_shifts_recovered(shift):
    """Odd (non-integer) shifts: the upsampled-DFT refinement resolves
    quarter-pixel displacements on a band-limited frame."""
    ref = smooth_image(64, seed=5)
    mov = np.asarray(apply_shift(ref, np.asarray(shift, np.float32)))
    got = np.asarray(register_phase_correlation(ref, mov, upsample_factor=8))
    np.testing.assert_allclose(got, [-shift[0], -shift[1]], atol=1 / 8 + 1e-6)


def test_subpixel_precision_scales_with_upsampling():
    ref = smooth_image(64, seed=6)
    mov = np.asarray(apply_shift(ref, (1.3, -2.6)))
    got = np.asarray(register_phase_correlation(ref, mov, upsample_factor=20))
    np.testing.assert_allclose(got, [-1.3, 2.6], atol=0.06)


def test_batched_registration_one_call():
    ref = smooth_image(32, seed=7)
    shifts = [(1.0, 2.0), (3.0, -4.0), (-5.0, 0.0)]
    movs = np.stack([np.asarray(apply_shift(ref, s)) for s in shifts])
    refs = np.broadcast_to(ref, movs.shape)
    got = np.asarray(register_phase_correlation(refs, movs))
    np.testing.assert_array_equal(got, [[-a, -b] for a, b in shifts])


def test_complex_frames_register():
    rng = np.random.default_rng(8)
    base = smooth_image(32, seed=9) + 1j * smooth_image(32, seed=10)
    ref = base.astype(np.complex64)
    mov = np.asarray(apply_shift(ref, (4.0, -3.0)))
    got = np.asarray(register_phase_correlation(ref, mov))
    np.testing.assert_array_equal(got, [-4.0, 3.0])
    del rng


def test_apply_shift_integer_matches_roll():
    rng = np.random.default_rng(11)
    x = rng.standard_normal((16, 32)).astype(np.float32)
    got = np.asarray(apply_shift(x, (3.0, -5.0)))
    np.testing.assert_allclose(got, np.roll(x, (3, -5), axis=(0, 1)), atol=1e-4)


def test_apply_shift_batched_per_frame_shifts():
    rng = np.random.default_rng(12)
    x = rng.standard_normal((2, 16, 16)).astype(np.float32)
    shifts = np.asarray([[1.0, 2.0], [-3.0, 4.0]], np.float32)
    got = np.asarray(apply_shift(x, shifts))
    for k in range(2):
        np.testing.assert_allclose(
            got[k],
            np.roll(x[k], tuple(shifts[k].astype(int)), axis=(0, 1)),
            atol=1e-4,
        )


def test_shape_mismatch_rejected():
    with pytest.raises(ValueError, match="share a shape"):
        register_phase_correlation(np.zeros((8, 8)), np.zeros((8, 16)))
    with pytest.raises(ValueError, match="dy, dx"):
        apply_shift(np.zeros((8, 8), np.float32), (1.0, 2.0, 3.0))


@pytest.mark.parametrize(
    "angle,scale",
    [(0.2, 1.0), (-0.2, 1.0), (0.0, 1.1), (0.0, 0.9), (0.3, 1.15)],
)
def test_logpolar_recovers_rotation_and_scale(angle, scale):
    ref = smooth_image(128, seed=3, bandwidth=0.1)
    mov = rotate_scale(ref, angle, scale)
    got_angle, got_scale = register_logpolar(ref, mov)
    assert got_angle == pytest.approx(angle, abs=0.02)
    assert got_scale == pytest.approx(scale, rel=0.02)


def test_logpolar_ignores_translation():
    """Magnitude spectra are shift-invariant: a translated+rotated frame
    reports the same rotation as the untranslated one."""
    ref = smooth_image(128, seed=4, bandwidth=0.1)
    mov = np.asarray(apply_shift(rotate_scale(ref, 0.25, 1.0), (9.0, -5.0)))
    got_angle, got_scale = register_logpolar(ref, mov)
    assert got_angle == pytest.approx(0.25, abs=0.03)
    assert got_scale == pytest.approx(1.0, rel=0.02)


def test_logpolar_identity_is_zero_motion():
    ref = smooth_image(64, seed=5, bandwidth=0.15)
    angle, scale = register_logpolar(ref, ref.copy())
    assert angle == pytest.approx(0.0, abs=1e-3)
    assert scale == pytest.approx(1.0, rel=1e-3)


def test_logpolar_input_contract():
    with pytest.raises(ValueError, match="single"):
        register_logpolar(np.zeros((2, 8, 8)), np.zeros((2, 8, 8)))
    with pytest.raises(ValueError, match="share a shape"):
        register_logpolar(np.zeros((8, 8)), np.zeros((16, 16)))
